"""Continuous-batching decode vs flush-batched decode (DESIGN.md §10).

The PR-6 acceptance bar: under an open-loop mixed-length load (mostly
short sequences plus a heavy-tail straggler per group), the
DecodeScheduler must deliver >= 2x the generated-tokens/sec of
flush-batched decode at 8 particles, with ZERO cold compiles after
warmup. Both sides run the identical stack — PagedDecodeEngine programs,
page pool, packed one-H2D step inputs — and differ only in admission:

  flush       submit ``max_active`` sequences, wait for ALL of them to
              retire before submitting the next group — finished rows
              idle at the barrier while the group straggler decodes;
  continuous  submit everything up front — rows refill from the waiting
              queue in the same step a sequence retires.

So the measured ratio isolates exactly what per-step admission buys.

Rows:
  decode/flush/p{P}        us_per_token, tok_per_s     (group barrier)
  decode/continuous/p{P}   us_per_token, tok_per_s + row occupancy
  decode/speedup/p{P}      ratio, x_over_flush
  decode/latency/p{P}      p50 us, p95/p99 derived     (continuous)
  decode/pages/p{P}        peak page occupancy, pool utilisation
  decode/compiles/p{P}     cold compiles in the timed region (want 0)

The PR-10 bar rides the same harness: with ``--speculative``, the
continuous-batching load is replayed twice over a near-identical
8-particle ensemble (one root, seven tiny-jitter clones, so the draft
particle's greedy proposals track the BMA argmax and acceptance is
high) — once through the plain scheduler, once through the speculative
one (DESIGN.md §14). Output is token-exact by construction; the ratio
isolates what draft-K-tokens/verify-once buys in dispatches per token:

  decode/spec_base/p{P}    plain continuous tok/s   (cloned ensemble)
  decode/spec/p{P}         speculative tok/s + acceptance_rate,
                           tokens_per_step, mean_k
  decode/spec_speedup/p{P} ratio, x_over_plain
  decode/spec_compiles/p{P} cold compiles in the timed region (want 0)

``python -m benchmarks.run --only decode`` persists the rows to
BENCH_decode.json; ``python -m benchmarks.bench_decode --require 2.0
--speculative --require-spec 1.3`` enforces the speedup +
zero-cold-compile bars (CI, both sharded matrix jobs).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import configs
from repro.core import ParticleModule, PushDistribution
from repro.models import api
from repro.runtime import global_cache
from repro.serve import serve_decode

from .util import emit

PARTICLES = (2, 8)
MAX_ACTIVE = 8
GROUPS = 3
SHORT_NEW, LONG_NEW = 6, 64          # one straggler per group
PAGE_SIZE = 8
NUM_PAGES = 96


def _lm_module(cfg):
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)


def _cfg():
    return configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=128)


def _load(rng):
    """Open-loop mixed-length request list: per group of MAX_ACTIVE, one
    heavy-tail straggler and MAX_ACTIVE-1 short sequences."""
    reqs = []
    for g in range(GROUPS):
        for j in range(MAX_ACTIVE):
            prompt = list(rng.integers(1, 128, int(rng.integers(3, 14))))
            max_new = LONG_NEW if j == 0 else SHORT_NEW
            reqs.append((prompt, max_new))
    return reqs


def _drive_flush(svc, reqs):
    """Group barrier: the defining waste of flush batching — no admission
    until the whole group retired."""
    t0 = time.perf_counter()
    toks = 0
    for g in range(0, len(reqs), MAX_ACTIVE):
        handles = [svc.generate_async(p, max_new=m)
                   for p, m in reqs[g:g + MAX_ACTIVE]]
        toks += sum(len(h.result(600.0).tokens) for h in handles)
    return time.perf_counter() - t0, toks


def _drive_continuous(svc, reqs):
    """Open loop: everything submitted up front, rows refill per step."""
    t0 = time.perf_counter()
    handles = [svc.generate_async(p, max_new=m) for p, m in reqs]
    toks = sum(len(h.result(600.0).tokens) for h in handles)
    return time.perf_counter() - t0, toks


def _clone_pd(cfg, P):
    """Near-identical ensemble: one root, P-1 tiny-jitter clones. The
    draft particle's greedy proposals then track the BMA argmax, so the
    speculative bench measures the accept-path steady state (high
    acceptance), not proposal quality."""
    pd = PushDistribution(_lm_module(cfg), num_devices=1, seed=0,
                          capacity=P)
    root = pd.p_create()
    for _ in range(P - 1):
        pd.p_clone(root, jitter=1e-3)
    return pd


def run_speculative(require_spec: float | None = None):
    """Speculative vs plain continuous decode on the same open-loop load
    and the same cloned ensemble (fresh store each side, identical seed
    -> identical params)."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    reqs = _load(rng)
    for P in PARTICLES:
        stats = {}
        for mode in ("plain", "spec"):
            with _clone_pd(cfg, P) as pd:
                svc = serve_decode(pd, cfg, num_pages=NUM_PAGES,
                                   page_size=PAGE_SIZE,
                                   max_active=MAX_ACTIVE,
                                   max_queue=4 * len(reqs),
                                   decode_kernel=False,
                                   warmup_buckets=(4, 8, 16),
                                   speculative=(mode == "spec"))
                try:
                    svc.generate(reqs[0][0], max_new=2)
                    cold0 = global_cache().snapshot_stats()["cold_compiles"]
                    dt, tok = _drive_continuous(svc, reqs)
                    cold = global_cache().snapshot_stats()["cold_compiles"] \
                        - cold0
                    stats[mode] = (dt, tok, cold, svc.stats())
                finally:
                    svc.close()
        (dt_b, tok_b, cold_b, _) = stats["plain"]
        (dt_s, tok_s, cold_s, st) = stats["spec"]
        ss = st["speculative"]
        emit(f"decode/spec_base/p{P}", dt_b / tok_b * 1e6,
             f"tok_per_s={tok_b / dt_b:.1f}")
        emit(f"decode/spec/p{P}", dt_s / tok_s * 1e6,
             f"tok_per_s={tok_s / dt_s:.1f};"
             f"acceptance_rate={ss['acceptance_rate']:.3f};"
             f"tokens_per_step={ss['tokens_per_step']:.2f};"
             f"mean_k={ss['mean_k']:.2f}")
        speedup = (tok_s / dt_s) / (tok_b / dt_b)
        emit(f"decode/spec_speedup/p{P}", speedup, "x_over_plain")
        emit(f"decode/spec_compiles/p{P}", float(cold_b + cold_s),
             "cold_compiles_after_warmup")

        if require_spec is not None and P == 8:
            if cold_s != 0:
                raise SystemExit(
                    f"{cold_s} cold compiles during steady-state "
                    "speculative decode (want 0 after warmup)")
            if speedup < require_spec:
                raise SystemExit(
                    f"speculative/plain decode speedup {speedup:.2f}x "
                    f"< required {require_spec:.1f}x at {P} particles "
                    f"(acceptance {ss['acceptance_rate']:.3f})")


def run(require: float | None = None, speculative: bool = False,
        require_spec: float | None = None):
    cfg = _cfg()
    rng = np.random.default_rng(0)
    reqs = _load(rng)
    for P in PARTICLES:
        with PushDistribution(_lm_module(cfg), num_devices=1, seed=0) as pd:
            for _ in range(P):
                pd.p_create()
            svc = serve_decode(pd, cfg, num_pages=NUM_PAGES,
                               page_size=PAGE_SIZE, max_active=MAX_ACTIVE,
                               max_queue=4 * len(reqs), decode_kernel=False,
                               warmup_buckets=(4, 8, 16))
            try:
                # warm every program the load can hit before timing
                svc.generate(reqs[0][0], max_new=2)
                cold0 = global_cache().snapshot_stats()["cold_compiles"]

                dt_f, tok_f = _drive_flush(svc, reqs)
                dt_c, tok_c = _drive_continuous(svc, reqs)
                cold = global_cache().snapshot_stats()["cold_compiles"] \
                    - cold0
                st = svc.stats()

                emit(f"decode/flush/p{P}", dt_f / tok_f * 1e6,
                     f"tok_per_s={tok_f / dt_f:.1f}")
                emit(f"decode/continuous/p{P}", dt_c / tok_c * 1e6,
                     f"tok_per_s={tok_c / dt_c:.1f};"
                     f"occupancy={st['row_occupancy']:.2f}")
                speedup = dt_f / dt_c
                emit(f"decode/speedup/p{P}", speedup, "x_over_flush")
                emit(f"decode/latency/p{P}", st["latency_p50_ms"] * 1e3,
                     f"p95_us={st['latency_p95_ms'] * 1e3:.0f};"
                     f"p99_us={st['latency_p99_ms'] * 1e3:.0f}")
                pool = st["pool"]
                emit(f"decode/pages/p{P}",
                     pool["peak_used"] / pool["num_pages"] * 1e2,
                     f"peak_used={pool['peak_used']};"
                     f"num_pages={pool['num_pages']};"
                     f"preempted={st['preempted']}")
                emit(f"decode/compiles/p{P}", float(cold),
                     "cold_compiles_after_warmup")

                if require is not None and P == 8:
                    if cold != 0:
                        raise SystemExit(
                            f"{cold} cold compiles during steady-state "
                            "decode (want 0 after warmup)")
                    if speedup < require:
                        raise SystemExit(
                            f"continuous/flush decode speedup "
                            f"{speedup:.2f}x < required {require:.1f}x "
                            f"at {P} particles")
            finally:
                svc.close()
    if speculative or require_spec is not None:
        run_speculative(require_spec=require_spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--require", type=float, default=None,
                    help="fail unless continuous/flush >= this at 8 "
                         "particles AND zero cold compiles after warmup "
                         "(acceptance: 2.0)")
    ap.add_argument("--speculative", action="store_true",
                    help="also run the speculative vs plain section")
    ap.add_argument("--require-spec", type=float, default=None,
                    help="fail unless speculative/plain continuous tok/s "
                         ">= this at 8 particles AND zero cold compiles "
                         "after warmup (acceptance: 1.3)")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    run(require=a.require, speculative=a.speculative,
        require_spec=a.require_spec)


if __name__ == "__main__":
    main()
