"""Paper Fig. 4 / Fig. 7: scaling of particles across devices per algorithm.

Measures time-per-epoch for deep ensembles, multi-SWAG and SVGD as the
particle count grows, through the Push particle runtime AND the paper's
handwritten baselines, on the paper's three workload families adapted to
this repo: ViT (vision), UNet-1D (PDE/SciML) and a tiny qwen-family LM.

``--backend compiled`` additionally lowers each algorithm through the
fused stacked-axis backend (DESIGN.md §3) — one XLA program over all
particles — so the runtime's dispatch overhead can be read directly off
the nel-vs-compiled gap at fixed particle count.

``--backend compiled-sharded`` further places the stacked state on a mesh
over every local device (ParticleStore placement, DESIGN.md §6) — the
paper's particle-scaling curves (Fig. 4: fixed model, growing particles
across devices). Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to emulate N
devices on CPU (structural validation; wall clock on one core is not).

Rows: scaling/<workload>/<algo>/<impl>/p<particles>,us_per_epoch,devices=<n>
where <impl> in {push, compiled, compiled-sharded, baseline}.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.bdl import DeepEnsemble, MultiSWAG, SteinVGD, baselines
from repro.core import Placement
from repro.data.loader import DataLoader
from repro.launch.mesh import make_bench_mesh
from repro.optim import adam, sgd

from .util import emit, timeit, tiny_module


def _data(cfg, num_batches: int, batch: int = 8):
    dl = DataLoader(cfg, batch_size=batch, seq_len=32, num_batches=num_batches)
    return [jax.tree.map(jnp.asarray, b) for b in dl]


def _run_push(num_devices, arch, mod, data, n):
    """Particle-runtime rows (backend="nel"): manual epoch drive so the
    measured quantity is pure runtime + step time, not particle init."""
    with DeepEnsemble(mod, num_devices=num_devices) as de:
        pids = [de.push_dist.p_create(adam(1e-3)) for _ in range(n)]

        def epoch():
            for b in data:
                de.push_dist.p_wait(
                    [de.push_dist.particles[p].step(b) for p in pids])
        us = timeit(lambda: epoch() or jnp.zeros(()))
    emit(f"scaling/{arch}/ensemble/push/p{n}", us, f"devices={num_devices}")

    with MultiSWAG(mod, num_devices=num_devices) as ms:
        ms.bayes_infer(data[:1], 1, optimizer=adam(1e-3),
                       num_particles=n, max_rank=4)  # build+jit
        pids = ms.push_dist.particle_ids()

        def epoch_sw():
            for b in data:
                ms.push_dist.p_wait(
                    [ms.push_dist.particles[p].step(b) for p in pids])
            ms.push_dist.p_wait(
                [ms.push_dist.p_launch(p, "SWAG_COLLECT") for p in pids])
        us = timeit(lambda: epoch_sw() or jnp.zeros(()))
    emit(f"scaling/{arch}/multiswag/push/p{n}", us, f"devices={num_devices}")

    with SteinVGD(mod, num_devices=num_devices) as sv:
        sv.bayes_infer(data[:1], 1, num_particles=n, lr=1e-3)  # jit
        us = timeit(lambda: sv.push_dist.p_wait(
            [sv.push_dist.p_launch(0, "SVGD_LEADER", 1e-3, 1.0,
                                   data, 1)]) and jnp.zeros(()))
    emit(f"scaling/{arch}/svgd/push/p{n}", us, f"devices={num_devices}")


def _run_compiled(num_devices, arch, mod, data, n):
    """Fused-backend rows: the real backend="compiled" epoch path
    (Infer._fused_epochs — stack, compiled loop, write back) on particles
    created outside the timed region, so the rows are directly comparable
    with the push/<n> rows (which also exclude particle creation)."""
    opt = adam(1e-3)

    with DeepEnsemble(mod, num_devices=num_devices, backend="compiled") as de:
        pids = [de.push_dist.p_create(opt) for _ in range(n)]
        de._fused_epochs(pids, data[:1], 1, optimizer=opt)  # build+jit
        us = timeit(lambda: (de._fused_epochs(pids, data, 1, optimizer=opt),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/ensemble/compiled/p{n}", us,
         f"devices={num_devices}")

    with MultiSWAG(mod, num_devices=num_devices, backend="compiled") as ms:
        pids = ms._create(opt, n, max_rank=4)
        ms._fused_epochs(pids, data[:1], 1, optimizer=opt)  # build+jit
        us = timeit(lambda: (ms._fused_epochs(pids, data, 1, optimizer=opt),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/multiswag/compiled/p{n}", us,
         f"devices={num_devices}")

    with SteinVGD(mod, num_devices=num_devices, backend="compiled") as sv:
        pids = sv._create(n)
        sv._fused_epochs(pids, data[:1], 1, lr=1e-3)  # build+jit
        us = timeit(lambda: (sv._fused_epochs(pids, data, 1, lr=1e-3),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/svgd/compiled/p{n}", us,
         f"devices={num_devices}")


def _run_compiled_sharded(arch, mod, data, n, model: int = 1):
    """Paper Fig. 4 reproduced through the sharded compiled path: the
    particle axis of the store's stacked state sharded over a mesh across
    every local device, the whole epoch as donated-buffer fused steps.
    ``model > 1`` carves a model axis out of the device count (2D
    particle x model placement, DESIGN.md §11) — tensor-parallel trailing
    dims ride it while particles take the rest."""
    ndev = len(jax.devices())
    placement = Placement(mesh=make_bench_mesh(ndev, model=model))
    opt = adam(1e-3)

    with DeepEnsemble(mod, num_devices=1, backend="compiled",
                      placement=placement) as de:
        pids = [de.push_dist.p_create(opt) for _ in range(n)]
        de._fused_epochs(pids, data[:1], 1, optimizer=opt)  # build+jit
        us = timeit(lambda: (de._fused_epochs(pids, data, 1, optimizer=opt),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/ensemble/compiled-sharded/p{n}", us,
         f"devices={ndev}")

    with MultiSWAG(mod, num_devices=1, backend="compiled",
                   placement=placement) as ms:
        pids = ms._create(opt, n, max_rank=4)
        ms._fused_epochs(pids, data[:1], 1, optimizer=opt)  # build+jit
        us = timeit(lambda: (ms._fused_epochs(pids, data, 1, optimizer=opt),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/multiswag/compiled-sharded/p{n}", us,
         f"devices={ndev}")

    with SteinVGD(mod, num_devices=1, backend="compiled",
                  placement=placement) as sv:
        pids = sv._create(n)
        sv._fused_epochs(pids, data[:1], 1, lr=1e-3)  # build+jit
        us = timeit(lambda: (sv._fused_epochs(pids, data, 1, lr=1e-3),
                             jnp.zeros(()))[1])
    emit(f"scaling/{arch}/svgd/compiled-sharded/p{n}", us,
         f"devices={ndev}")


def _run_baselines(num_devices, arch, mod, data, n):
    opt_b = adam(1e-3)
    us = timeit(
        lambda: (baselines.ensemble_baseline(mod, opt_b, n,
                                             data, 1), jnp.zeros(()))[1],
        iters=2)
    emit(f"scaling/{arch}/ensemble/baseline/p{n}", us,
         f"devices={num_devices}")

    us = timeit(lambda: (baselines.svgd_baseline(
        mod, n, data, 1, lr=1e-3), jnp.zeros(()))[1], iters=2)
    emit(f"scaling/{arch}/svgd/baseline/p{n}", us,
         f"devices={num_devices}")


def run(num_devices: int = 1, particles=(1, 2, 4), num_batches: int = 3,
        workloads=("vit-mnist", "unet-advection", "qwen1.5-0.5b"),
        backend: str = "nel", model: int = 1):
    for arch in workloads:
        mod = tiny_module(arch)
        data = _data(mod.cfg, num_batches)
        for n in particles:
            _run_push(num_devices, arch, mod, data, n)
            if backend in ("compiled", "compiled-sharded"):
                _run_compiled(num_devices, arch, mod, data, n)
            if backend == "compiled-sharded":  # the particle-scaling curve
                _run_compiled_sharded(arch, mod, data, n, model=model)
            _run_baselines(num_devices, arch, mod, data, n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--particles", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--backend",
                    choices=("nel", "compiled", "compiled-sharded"),
                    default="nel")
    ap.add_argument("--model", type=int, default=1,
                    help="model-axis size for the compiled-sharded rows "
                         "(2D particle x model placement; must divide the "
                         "device count). Implies --backend "
                         "compiled-sharded when > 1")
    ap.add_argument("--json", default="BENCH_scaling.json",
                    help="where to persist the scaling rows when run "
                         "standalone (benchmarks.run also writes this)")
    a = ap.parse_args()
    backend = "compiled-sharded" if a.model > 1 else a.backend
    print("name,us_per_call,derived")
    run(a.devices, tuple(a.particles), a.batches, backend=backend,
        model=a.model)
    import json

    from .util import ROWS
    rows = [r for r in ROWS if r["name"].startswith("scaling/")]
    with open(a.json, "w") as f:
        json.dump({"devices": len(jax.devices()), "backend": backend,
                   "model_axis": a.model, "rows": rows}, f, indent=1)
    print(f"# wrote {len(rows)} scaling rows -> {a.json}", flush=True)


if __name__ == "__main__":
    main()
