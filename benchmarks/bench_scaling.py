"""Paper Fig. 4 / Fig. 7: scaling of particles across devices per algorithm.

Measures time-per-epoch for deep ensembles, multi-SWAG and SVGD as the
particle count grows, through the Push particle runtime AND the paper's
handwritten baselines, on the paper's three workload families adapted to
this repo: ViT (vision), UNet-1D (PDE/SciML) and a tiny qwen-family LM.

Rows: scaling/<workload>/<algo>/<impl>/p<particles>,us_per_epoch,devices=<n>
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.bdl import DeepEnsemble, MultiSWAG, SteinVGD, baselines
from repro.data.loader import DataLoader
from repro.optim import adam, sgd

from .util import emit, timeit, tiny_module


def _data(cfg, num_batches: int, batch: int = 8):
    dl = DataLoader(cfg, batch_size=batch, seq_len=32, num_batches=num_batches)
    return [jax.tree.map(jnp.asarray, b) for b in dl]


def run(num_devices: int = 1, particles=(1, 2, 4), num_batches: int = 3,
        workloads=("vit-mnist", "unet-advection", "qwen1.5-0.5b")):
    for arch in workloads:
        mod = tiny_module(arch)
        data = _data(mod.cfg, num_batches)

        for n in particles:
            # --- deep ensemble (Push) -----------------------------------
            with DeepEnsemble(mod, num_devices=num_devices) as de:
                pids = [de.push_dist.p_create(adam(1e-3)) for _ in range(n)]

                def epoch():
                    for b in data:
                        de.push_dist.p_wait(
                            [de.push_dist.particles[p].step(b) for p in pids])
                us = timeit(lambda: epoch() or jnp.zeros(()))
            emit(f"scaling/{arch}/ensemble/push/p{n}", us,
                 f"devices={num_devices}")

            # --- multi-SWAG (Push) ---------------------------------------
            with MultiSWAG(mod, num_devices=num_devices) as ms:
                ms.bayes_infer(data[:1], 1, optimizer=adam(1e-3),
                               num_particles=n, max_rank=4)  # build+jit
                pids = ms.push_dist.particle_ids()

                def epoch_sw():
                    for b in data:
                        ms.push_dist.p_wait(
                            [ms.push_dist.particles[p].step(b) for p in pids])
                    ms.push_dist.p_wait(
                        [ms.push_dist.p_launch(p, "SWAG_COLLECT") for p in pids])
                us = timeit(lambda: epoch_sw() or jnp.zeros(()))
            emit(f"scaling/{arch}/multiswag/push/p{n}", us,
                 f"devices={num_devices}")

            # --- SVGD (Push, message passing) ----------------------------
            with SteinVGD(mod, num_devices=num_devices) as sv:
                sv.bayes_infer(data[:1], 1, num_particles=n, lr=1e-3)  # jit
                us = timeit(lambda: sv.push_dist.p_wait(
                    [sv.push_dist.p_launch(0, "SVGD_LEADER", 1e-3, 1.0,
                                           data, 1)]) and jnp.zeros(()))
            emit(f"scaling/{arch}/svgd/push/p{n}", us,
                 f"devices={num_devices}")

            # --- handwritten baselines (paper Fig. 4 grey curves) ---------
            opt_b = adam(1e-3)
            us = timeit(
                lambda: (baselines.ensemble_baseline(mod, opt_b, n,
                                                     data, 1), jnp.zeros(()))[1],
                iters=2)
            emit(f"scaling/{arch}/ensemble/baseline/p{n}", us,
                 f"devices={num_devices}")

            us = timeit(lambda: (baselines.svgd_baseline(
                mod, n, data, 1, lr=1e-3), jnp.zeros(()))[1], iters=2)
            emit(f"scaling/{arch}/svgd/baseline/p{n}", us,
                 f"devices={num_devices}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--particles", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--batches", type=int, default=3)
    a = ap.parse_args()
    run(a.devices, tuple(a.particles), a.batches)


if __name__ == "__main__":
    main()
