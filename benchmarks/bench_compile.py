"""Runtime-layer compile economics across the train -> serve lifecycle.

Measures what the shared ProgramCache (repro.runtime) buys: how many cold
compiles one full lifecycle costs (fused training, fused predict, a
micro-batched service over mixed request sizes, then a SECOND service
over the same store), the cache hit rate, and the cold-vs-warm call
latency gap per program family.

Rows (``compile/...``) land in BENCH_runtime.json via ``run.py --only
compile``; CI gates on ``--require-hit-rate`` — if the lifecycle's hit
rate drops below the floor, some path stopped sharing programs (a
regression to the pre-runtime world of one private cache per subsystem).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.bdl import DeepEnsemble
from repro.data.synthetic import mnist_like
from repro.optim import sgd
from repro.runtime import global_cache

from .util import emit, tiny_module

N_PARTICLES = 4
EPOCHS = 10
BATCH = 16
# a serving burst: mixed sizes, each bucket hit more than once (the
# steady-state mix the hit-rate gate models)
SERVE_SIZES = (1, 2, 3, 4, 5, 7, 8, 8, 3, 5, 1, 6, 2, 8, 4, 7)


def _delta(before, after):
    return {k: after[k] - before[k] for k in
            ("hits", "misses", "cold_compiles")}


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def run(require_hit_rate: float = 0.0) -> int:
    cache = global_cache()
    mod = tiny_module()
    batch = mnist_like(np.random.default_rng(0), BATCH)
    data = [batch]
    probe = batch

    t_start = cache.snapshot_stats()
    with DeepEnsemble(mod, backend="compiled", seed=0) as de:
        # -- train: one ensemble_step program, reused every epoch --------
        before = cache.snapshot_stats()
        us = _timed(lambda: de.bayes_infer(
            data, EPOCHS, optimizer=sgd(0.05), num_particles=N_PARTICLES))
        d = _delta(before, cache.snapshot_stats())
        emit("compile/train_epochs", us,
             f"cold={d['cold_compiles']} hits={d['hits']}")

        # -- fused predict: cold then warm -------------------------------
        cold_us = _timed(lambda: de.posterior_pred(probe))
        warm_us = _timed(lambda: de.posterior_pred(probe))
        emit("compile/predict_cold", cold_us, "first call (compiles)")
        emit("compile/predict_warm", warm_us,
             f"speedup={cold_us / max(warm_us, 1e-9):.1f}x")

        # -- serve: mixed batch sizes share power-of-two buckets ---------
        imgs = batch["images"]
        before = cache.snapshot_stats()
        with de.posterior_predictive(kind="classify") as svc:
            us = _timed(lambda: [svc.predict_batch({"images": imgs[:m]})
                                 for m in SERVE_SIZES])
        d = _delta(before, cache.snapshot_stats())
        emit("compile/serve_mixed_batches", us,
             f"cold={d['cold_compiles']} hits={d['hits']} "
             f"({len(SERVE_SIZES)} sizes)")

        # -- second service over the same store: must compile nothing ----
        before = cache.snapshot_stats()
        with de.posterior_predictive(kind="classify") as svc2:
            us = _timed(lambda: [svc2.predict_batch({"images": imgs[:m]})
                                 for m in (8, 4, 2)])
        d = _delta(before, cache.snapshot_stats())
        emit("compile/second_service", us,
             f"cold={d['cold_compiles']} hits={d['hits']}")
        second_cold = d["cold_compiles"]

    total = _delta(t_start, cache.snapshot_stats())
    seen = total["hits"] + total["misses"]
    hit_rate = total["hits"] / seen if seen else 0.0
    emit("compile/lifecycle", 0.0,
         f"cold={total['cold_compiles']} hit_rate={hit_rate:.3f}")

    if second_cold != 0:
        print(f"# FAIL: second service cold-compiled {second_cold} "
              "programs (cross-engine reuse broken)", flush=True)
        return 1
    if hit_rate < require_hit_rate:
        print(f"# FAIL: lifecycle hit rate {hit_rate:.3f} < required "
              f"{require_hit_rate:.3f}", flush=True)
        return 1
    if require_hit_rate:
        print(f"# PASS: hit rate {hit_rate:.3f} >= {require_hit_rate:.3f}, "
              "second service compiled nothing", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--require-hit-rate", type=float, default=0.0,
                    help="exit nonzero if the lifecycle cache hit rate "
                         "falls below this floor (CI gate)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    return run(require_hit_rate=args.require_hit_rate)


if __name__ == "__main__":
    sys.exit(main())
