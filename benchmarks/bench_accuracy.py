"""Paper Tables 3-4: multi-SWAG accuracy vs standard training at fixed
effective parameter count (depth halved <-> particles doubled), on the
synthetic MNIST-like task.

Rows: accuracy/<standard|multiswag>/d<depth>_p<particles>,us,acc=<value>
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.bdl import MultiSWAG
from repro.core import ParticleModule
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam
from repro import configs

from .util import emit


def _module(depth: int):
    cfg = configs.get("vit-mnist").smoke().replace(
        n_units=depth, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96)
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)


def _acc(logits, labels):
    return float(jnp.mean((jnp.argmax(logits, -1) == labels)))


def run(pairs=((4, 1), (2, 2), (1, 4)), epochs: int = 6, num_batches: int = 6):
    for depth, n in pairs:
        mod = _module(depth)
        train = [jax.tree.map(jnp.asarray, b) for b in
                 DataLoader(mod.cfg, batch_size=16, num_batches=num_batches,
                            seed=0)]
        test = [jax.tree.map(jnp.asarray, b) for b in
                DataLoader(mod.cfg, batch_size=64, num_batches=2, seed=99)]

        # standard training: 1 particle, plain Adam
        t0 = time.perf_counter()
        params = mod.init(jax.random.PRNGKey(0))
        opt = adam(2e-3)
        st = opt.init(params)
        step = jax.jit(lambda p, s, b: _train_step(mod, opt, p, s, b))
        for _ in range(epochs):
            for b in train:
                params, st, _ = step(params, st, b)
        accs = [_acc(mod._forward(params, b), b["labels"]) for b in test]
        emit(f"accuracy/standard/d{depth}_p1",
             (time.perf_counter() - t0) * 1e6, f"acc={sum(accs)/len(accs):.4f}")

        # multi-SWAG: n particles, same effective parameter count
        t0 = time.perf_counter()
        with MultiSWAG(mod, num_devices=1) as ms:
            ms.bayes_infer(train, epochs, optimizer=adam(2e-3),
                           num_particles=n, pretrain_epochs=epochs // 2,
                           max_rank=4)
            accs = [_acc(ms.sample_predict(b, samples_per_particle=3),
                         b["labels"]) for b in test]
        emit(f"accuracy/multiswag/d{depth}_p{n}",
             (time.perf_counter() - t0) * 1e6, f"acc={sum(accs)/len(accs):.4f}")


def _train_step(mod, opt, params, st, batch):
    (l, _), g = jax.value_and_grad(lambda p: mod.loss(p, batch),
                                   has_aux=True)(params)
    params, st = opt.update(params, g, st)
    return params, st, l


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
