"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp oracle,
plus the fused-vs-message-passing SVGD step comparison (EXPERIMENTS.md
§Perf: paper-faithful NEL runtime vs the compiled stacked-particle path).

Rows: kernels/<name>,us_per_call,<impl/shape>
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.bdl import SteinVGD, fused_svgd_step
from repro.core import functional
from repro.data.loader import DataLoader
from repro.kernels import ops, ref
from repro.optim import sgd

from .util import emit, timeit, tiny_module


def run():
    # --- SVGD force: jnp oracle vs Pallas-interpret ------------------------
    for n, D in [(8, 100_000), (32, 100_000)]:
        t = jax.random.normal(jax.random.PRNGKey(0), (n, D)) * 0.05
        g = jax.random.normal(jax.random.PRNGKey(1), (n, D))
        jref = jax.jit(lambda a, b: ref.svgd_force(a, b, 1.0))
        emit(f"kernels/svgd_force_ref_n{n}_D{D}", timeit(jref, t, g), "jnp")
        emit(f"kernels/svgd_force_pallas_n{n}_D{D}",
             timeit(lambda a, b: ops.svgd_force(a, b, jnp.float32(1.0)), t, g),
             "pallas-interpret")

    # --- SWAG moments -------------------------------------------------------
    D = 500_000
    m = jnp.zeros((D,))
    p = jax.random.normal(jax.random.PRNGKey(2), (D,))
    jref = jax.jit(lambda m_, p_: ref.swag_moments(m_, m_, p_, 3.0))
    emit(f"kernels/swag_moments_ref_D{D}", timeit(jref, m, p), "jnp")
    from repro.kernels import swag_moments as sm
    emit(f"kernels/swag_moments_pallas_D{D}",
         timeit(jax.jit(lambda m_, p_: sm.moments_flat(m_, m_, p_, 3.0)), m, p),
         "pallas-interpret")

    # --- flash attention ----------------------------------------------------
    B, S, H, KVH, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    emit(f"kernels/flash_ref_S{S}",
         timeit(jax.jit(lambda a, b, c: ref.flash_attention(a, b, c)), q, k, v),
         "jnp-naive")
    emit(f"kernels/flash_pallas_S{S}",
         timeit(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v),
         "pallas-interpret")

    # --- SVGD: paper-faithful message passing vs compiled fused step -------
    mod = tiny_module("vit-mnist", n_units=1, d_model=32)
    data = [jax.tree.map(jnp.asarray, b) for b in
            DataLoader(mod.cfg, batch_size=4, num_batches=2)]
    n = 4
    with SteinVGD(mod, num_devices=1) as sv:
        sv.bayes_infer(data[:1], 1, num_particles=n, lr=1e-3)
        us_mp = timeit(lambda: sv.push_dist.p_wait(
            [sv.push_dist.p_launch(0, "SVGD_LEADER", 1e-3, 1.0, data, 1)])
            and jnp.zeros(()), iters=2)
    emit("svgd_impl/message_passing_p4", us_mp, "paper-faithful NEL")

    stacked = functional.init_stacked(mod, n, jax.random.PRNGKey(0))
    fstep = jax.jit(fused_svgd_step(mod.loss, lr=1e-3, lengthscale=1.0))

    def fused_epoch(s):
        for b in data:
            s, _ = fstep(s, b)
        return s
    emit("svgd_impl/fused_p4", timeit(fused_epoch, stacked, iters=2),
         "compiled stacked-particle")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
