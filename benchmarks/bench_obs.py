"""Tracing-overhead microbenchmark: what does repro.obs cost the hot path?

The workload is bench_dispatch's: round-robin no-op dispatches through
one Executor worker, ~10-15µs per message on this container. The gate
question is what instrumentation adds per message in each mode:

  disabled   instrument=True, tracing off — the shipping default. Cost:
             one tracer-attribute load + enabled-flag branch per item.
  enabled    tracing on — the full record path (cached-tid lookup, args
             dict, tuple build, ring append, counter).

A wall-clock A/B of the two executor modes cannot resolve a 1% gate on
a single-core container — the per-item floor drifts by 5-10% between
measurement windows seconds apart (observed on the *disabled* mode,
whose run loop differs from baseline by one branch). So the benchmark
measures the denominator end to end (best-of-iters per-message time,
uninstrumented) and the numerator directly: the exact per-item guard /
record sequences from ``Executor._run_item``, timed over ``reps``
iterations with the empty-loop cost subtracted — stable to nanoseconds.
Overhead = per-item instrumentation cost / per-message baseline.

Rows: obs/baseline/p<n> (end-to-end µs/msg), obs/<mode>/p<n> (µs/msg
with the mode's per-item cost added; derived column carries the gated
overhead_pct), obs/summary/* (the two gated percentages). Gates:
``--require-disabled`` / ``--require-enabled`` as fractions of baseline
(ISSUE-8 acceptance: disabled <= 1%, enabled <= 5%).
"""
from __future__ import annotations

import argparse
import gc
import threading
import time

from repro.core.executor import Executor
from repro.obs import trace

from .util import emit


def _noop():
    return None


def _drive(ex: Executor, particles: int, messages: int) -> float:
    t0 = time.perf_counter()
    futs = [ex.submit(i % particles, _noop) for i in range(messages)]
    for f in futs:
        f.wait()
    return time.perf_counter() - t0


def _baseline(particles: int, messages: int, iters: int) -> float:
    """Best-of-iters seconds per message, instrument=False (no tracer
    reference in the run loop at all)."""
    trace.disable()
    best = float("inf")
    ex = Executor(num_devices=1, pool_size=0, max_pending=2 * messages,
                  instrument=False)
    for pid in range(particles):
        ex.add_particle(pid, 0)
    try:
        gc.collect()
        gc.disable()
        try:
            for _ in range(iters + 1):      # first drive is warmup
                best = min(best, _drive(ex, particles, messages))
        finally:
            gc.enable()
    finally:
        ex.shutdown()
    return best / messages


def _timed_loop(body, reps: int) -> float:
    """Best-of-3 seconds per rep with the bare-loop cost subtracted."""
    def once(fn):
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    def empty():
        for _ in range(reps):
            pass

    return max(0.0, once(body) - once(empty)) / reps


def _guard_cost(reps: int) -> float:
    """The disabled path: what ``_run_item`` pays per item when tracing
    is off — load the tracer, check the flag, fall through."""
    tr = trace.TRACER
    trace.disable()

    def body():
        for _ in range(reps):
            if tr is not None and tr.enabled:
                raise AssertionError

    return _timed_loop(body, reps)


def _record_cost(reps: int) -> float:
    """The enabled path: the exact inlined record sequence from
    ``_run_item`` — cached-tid getattr, args dict, span tuple, ring
    append, recorded counter."""
    tr = trace.TRACER
    trace.clear()
    trace.enable(ring=65536)
    tlocal = threading.local()
    t0 = time.perf_counter()

    def body():
        for i in range(reps):
            if tr is not None and tr.enabled:
                tid = getattr(tlocal, "tid", None)
                if tid is None:
                    tid = tlocal.tid = threading.get_ident()
                tr._buf.append(("executor.run", "executor", t0, t0, tid,
                                {"pid": i & 7, "queue": 0,
                                 "wait_ms": (t0 - t0) * 1e3}))
                tr._recorded += 1

    try:
        return _timed_loop(body, reps)
    finally:
        trace.disable()
        trace.clear()


def run(particles: int = 8, messages: int = 4000, iters: int = 5,
        reps: int = 200_000):
    base = _baseline(particles, messages, iters)
    emit(f"obs/baseline/p{particles}", base * 1e6, "overhead_pct=0.0")
    modes = {}
    for mode, cost in (("disabled", _guard_cost(reps)),
                       ("enabled", _record_cost(reps))):
        over = cost / base
        modes[mode] = over
        emit(f"obs/{mode}/p{particles}", (base + cost) * 1e6,
             f"overhead_pct={over * 100:.2f}")
    emit("obs/summary/disabled_overhead", modes["disabled"] * 1e2,
         "pct_vs_baseline")
    emit("obs/summary/enabled_overhead", modes["enabled"] * 1e2,
         "pct_vs_baseline")
    return modes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=8)
    ap.add_argument("--messages", type=int, default=4000)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--reps", type=int, default=200_000,
                    help="iterations for the per-item cost loops")
    ap.add_argument("--require-disabled", type=float, default=0.0,
                    help="fail if disabled-tracing overhead exceeds this "
                         "fraction of baseline (e.g. 0.01 = 1%%)")
    ap.add_argument("--require-enabled", type=float, default=0.0,
                    help="fail if enabled-tracing overhead exceeds this "
                         "fraction of baseline (e.g. 0.05 = 5%%)")
    a = ap.parse_args()
    modes = run(a.particles, a.messages, a.iters, a.reps)
    if a.require_disabled and modes["disabled"] > a.require_disabled:
        raise SystemExit(
            f"disabled-tracing overhead {modes['disabled']:.2%} exceeds "
            f"{a.require_disabled:.2%}")
    if a.require_enabled and modes["enabled"] > a.require_enabled:
        raise SystemExit(
            f"enabled-tracing overhead {modes['enabled']:.2%} exceeds "
            f"{a.require_enabled:.2%}")


if __name__ == "__main__":
    main()
